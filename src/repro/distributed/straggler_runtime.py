"""Straggler policies applied to distributed training pods (beyond-paper).

In synchronous SPMD training every collective waits for the slowest host,
so one straggler host taxes the whole step. Prior systems detect this
reactively (timeout, then restart); START's insight — predict the latency
*tail* from host+work features over a Pareto model — transfers directly.

This module is the pod-side *substrate* of the unified policy API
(``repro.policy``): it accumulates per-step telemetry, publishes the same
:class:`~repro.policy.telemetry.TelemetryView` the cloud simulator
publishes, and executes the unified :class:`~repro.policy.Action`
vocabulary.  Task-level verbs are translated to pod semantics
(DESIGN.md §6):

  * SPECULATE/CLONE -> backup shards: a healthy host also computes the
    predicted straggler's microbatch; at the gradient reduce a
    first-done-wins mask keeps exactly one contribution (gradient-exact).
  * RERUN/EVICT -> evict-and-remesh: chronic stragglers are dropped at a
    step boundary; repro.distributed.elastic rebuilds the mesh and state
    is restored from the latest checkpoint.
  * DELAY has no pod analogue and is ignored.

Because both substrates speak one view/action vocabulary, cloud baselines
port over: ``StragglerRuntime(cfg, policy=IGRUSD())`` runs the paper's
IGRU-SD baseline on a training pod (see ``pretrain_igru_pod``).  The pod
maps each host's current *horizon-step window* to one synthetic "task":
all hosts complete the same shard work per step (synchronous SPMD), so
progress advances uniformly while per-host elapsed time carries the
slowdown — exactly the progress/elapsed/expected geometry the cloud
policies reason about.

The default policy, :class:`StartPodPolicy`, is START's Algorithm 1
mapped to pod semantics: E_S (Eq. 4) from the fitted step-time tail
sizes the speculative backup set, chronic stragglers are evicted.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import features, pareto
from repro.policy import (Action, ActionKind, EVENT_INTERVAL, Policy,
                          TelemetryView, host_action, register)
from repro.policy.telemetry import (CANCELLED, RUNNING, HostTelemetry,
                                    JobTelemetry, TaskTelemetry, readonly)

#: legacy constructor name: a host-level Action (kind, host, backup=...)
HostAction = host_action


@dataclasses.dataclass
class RuntimeConfig:
    n_hosts: int
    horizon: int = 5
    k: float = 1.5
    evict_after: int = 3        # consecutive straggler intervals -> evict
    ma_decay: float = 0.8
    seed: int = 0

    #: the pod's normalized clock: fleet-median step time == 1.0 "second"
    #: of work at unit speed, so policy-side expected-time math holds
    host_ips_mean: float = 1.0
    max_tasks: int = 1


def fitted_tail(step_times: list, horizon: int) -> tuple[float, float]:
    """MLE Pareto fit over the recent per-host step times."""
    recent = np.concatenate(step_times[-horizon:])
    recent = recent[recent > 0]
    a, b = pareto.fit_pareto(np.asarray(recent, np.float32))
    return float(a), float(b)


def expected_stragglers(step_times: list, n_hosts: int, k: float,
                        horizon: int) -> float:
    """E_S (Eq. 4) from the fitted step-time tail."""
    if not step_times:
        return 0.0
    a, b = fitted_tail(step_times, horizon)
    return float(pareto.expected_stragglers(float(n_hosts), a, b, k))


@register("start-pod", substrates=("pod",),
          description="START's Algorithm 1 on pod semantics: Pareto-tail "
                      "E_S sizes the backup-shard set, chronic stragglers "
                      "are evicted")
class StartPodPolicy(Policy):
    """Algorithm 1 per training interval.

    Chronic stragglers are evicted unconditionally (a host that is slow
    ``evict_after`` intervals in a row delays every step regardless of
    the tail estimate); E_S sizes the *speculative* backup set, exactly
    as floor(E_S) sizes the mitigation set in the paper.  All state it
    reads comes from the runtime's TelemetryView: raw step times under
    ``view.extra``, the straggler moving average as
    ``view.straggler_ma``, eviction status as host downtime.
    """

    name = "start-pod"

    def _expected_stragglers(self, view: TelemetryView) -> float:
        """E_S for the current interval — the prediction seam.  The base
        policy fits the MLE Pareto tail over recent step times;
        subclasses swap in the Encoder-LSTM (online-trained or served)
        without touching the trigger/translation logic."""
        cfg = view.config
        return expected_stragglers(view.extra["step_times"], cfg.n_hosts,
                                   cfg.k, cfg.horizon)

    def decide(self, view: TelemetryView) -> list[Action]:
        cfg = view.config
        step_times = view.extra.get("step_times", ())
        if not step_times:
            return []
        online = view.hosts.online()
        chronic = view.extra["chronic"]
        actions: list[Action] = []
        evicting: set[int] = set()
        for h in np.nonzero(chronic >= cfg.evict_after)[0]:
            h = int(h)
            if online[h]:
                actions.append(host_action(ActionKind.EVICT, h))
                evicting.add(h)
        e_s = self._expected_stragglers(view)
        n_mit = int(math.floor(e_s))
        if n_mit <= 0:
            return actions
        last = step_times[-1]
        order = np.argsort(-last)  # slowest first
        healthy = [int(h) for h in np.argsort(view.straggler_ma)
                   if online[h] and int(h) not in evicting]
        hi = 0
        acted = {a.host for a in actions}
        for h in order[:n_mit]:
            h = int(h)
            if not online[h] or h in evicting or h in acted:
                continue
            while hi < len(healthy) and healthy[hi] == h:
                hi += 1
            backup = healthy[hi % len(healthy)] if healthy else h
            hi += 1
            actions.append(host_action(ActionKind.BACKUP_SHARD, h,
                                       backup=backup))
        return actions


@register("start-eager-pod", substrates=("pod",),
          description="START's per-task predicted-straggler trigger on "
                      "pod semantics: hosts in the predicted set get "
                      "backup shards after a hysteresis streak, chronic "
                      "stragglers are evicted")
class StartEagerPodPolicy(StartPodPolicy):
    """The per-task eager trigger translated to pod semantics.

    :class:`StartPodPolicy` only launches backups once the fitted tail's
    floor(E_S) reaches 1 — the pod analogue of the simulator's late
    completion-milestone trigger.  Here a host enters the predicted
    straggler set when it either ranks among the top-floor(E_S) slowest
    of the last step or exceeds the per-interval straggler threshold
    (relative step time > k, the same signal the runtime's chronic
    counter uses); it gets a backup shard after ``hysteresis``
    consecutive in-set steps and then rests ``cooldown`` steps, so a
    host flapping around the threshold cannot spam backups.  Chronic
    stragglers are evicted exactly as in the base policy.  Per-host
    streak state is dropped on ``forget_tasks`` (the runtime rebinds the
    per-host task ids at every horizon-window boundary).
    """

    name = "start-eager-pod"

    def __init__(self, hysteresis: int = 2, cooldown: int = 5):
        self.hysteresis = hysteresis
        self.cooldown = cooldown
        self._tick = 0
        self._streak: dict[int, int] = {}
        self._cool: dict[int, int] = {}

    def forget_tasks(self, task_ids) -> None:
        for t in task_ids:
            t = int(t)
            self._streak.pop(t, None)
            self._cool.pop(t, None)

    def decide(self, view: TelemetryView) -> list[Action]:
        cfg = view.config
        step_times = view.extra.get("step_times", ())
        if not step_times:
            return []
        self._tick += 1
        online = view.hosts.online()
        chronic = view.extra["chronic"]
        actions: list[Action] = []
        unavailable: set[int] = set()
        for h in np.nonzero(chronic >= cfg.evict_after)[0]:
            h = int(h)
            if online[h]:
                actions.append(host_action(ActionKind.EVICT, h))
                unavailable.add(h)
        last = np.asarray(step_times[-1], float)
        med = np.median(last[last > 0]) if (last > 0).any() else 1.0
        rel = last / max(med, 1e-9)
        e_s = self._expected_stragglers(view)
        n_pred = int(math.floor(e_s)) if math.isfinite(e_s) else 0
        n_pred = min(max(n_pred, 0), cfg.n_hosts)
        members = {int(h) for h in np.argsort(-rel)[:n_pred]}
        members |= {int(h) for h in np.nonzero(rel > cfg.k)[0]}
        for h in sorted(members, key=lambda i: (-rel[i], i)):
            if not online[h] or h in unavailable:
                continue
            streak = self._streak.get(h, 0) + 1
            self._streak[h] = streak
            if streak < self.hysteresis \
                    or self._cool.get(h, 0) > self._tick:
                continue
            # backup host left to the runtime's lowest-MA pick
            actions.append(host_action(ActionKind.BACKUP_SHARD, h))
            self._cool[h] = self._tick + self.cooldown
            self._streak[h] = 0
        for h in [h for h in self._streak if h not in members]:
            del self._streak[h]
        return actions


@register("start-pod-online", substrates=("pod",),
          description="start-pod with the Encoder-LSTM trained online "
                      "on completed step windows: E_S comes from the "
                      "network once enough windows have been fit, the "
                      "MLE tail until then")
class OnlineStartPodPolicy(StartPodPolicy):
    """START's full pipeline on the pod, trained online.

    :class:`StartPodPolicy` only ever runs the paper's *fallback* — the
    MLE Pareto fit over raw step times (no Encoder-LSTM).  This policy
    closes the gap: every completed horizon-step window becomes one
    training pair through the predictor's standard ``fit()`` path (the
    pod is one ``n_hosts``-task job; targets are the MLE fit of the
    window's per-host elapsed times, the same construction the
    simulator's offline pretrainer uses), and once ``min_windows`` pairs
    have been absorbed, E_S comes from the network's (alpha, beta) head
    instead of the raw-tail fit.  Everything downstream — backup-set
    sizing, eviction, hysteresis in the eager subclass — is inherited
    unchanged through the ``_expected_stragglers`` seam.
    """

    name = "start-pod-online"

    def __init__(self, epochs_per_update: int = 8, lr: float = 1e-3,
                 min_windows: int = 2, seed: int = 0):
        self.epochs_per_update = epochs_per_update
        self.lr = lr
        self.min_windows = min_windows
        self.seed = seed
        self.predictor = None
        self._seen = 0              # completed windows already trained on
        self._xs: list[np.ndarray] = []
        self._ys: list[list[float]] = []
        self.trained_pairs = 0

    # ---------------- feature construction (pod -> paper matrices) ------

    @staticmethod
    def _m_h(util: np.ndarray) -> np.ndarray:
        """(n, 4) pod utilization -> (n, HOST_FEATURES) M_H.  The pod
        has no price/power/capacity telemetry: capacities, cost and
        power normalize to ones (homogeneous fleet), n_tasks is one
        shard per host."""
        n = util.shape[0]
        ones = np.ones(n, np.float32)
        return features.host_matrix_np(
            np.clip(util, 0.0, 2.0), np.ones((n, 4), np.float32),
            ones, ones, np.ones(n, np.int64))

    @staticmethod
    def _m_t(util: np.ndarray) -> np.ndarray:
        """(n, 4) pod utilization -> (n, TASK_FEATURES) M_T: each host's
        shard "requires" what the host currently burns; previous host is
        the host itself (shards are pinned)."""
        n = util.shape[0]
        return features.task_matrix_batch_np(
            np.clip(util, 0.0, 1.0), np.arange(n),
            np.zeros(n, np.int64), np.arange(n), 1, n, n)[0]

    def _host_window(self, util_history: list,
                     t_end: int, horizon: int) -> np.ndarray:
        """Trailing ``horizon`` M_H rows ending at step ``t_end``
        (1-based), left-clamped to the first observation — the same
        windowing as ``NoOpRecorder.dataset``."""
        idx = np.maximum(np.arange(t_end - horizon, t_end), 0)
        idx = np.minimum(idx, len(util_history) - 1)
        return np.stack([self._m_h(np.asarray(util_history[i],
                                              np.float32))
                         for i in idx])

    # ---------------- online training -----------------------------------

    def _ensure_predictor(self, cfg) -> None:
        if self.predictor is None:
            from repro.core.predictor import StragglerPredictor
            self.predictor = StragglerPredictor(
                n_hosts=cfg.n_hosts, max_tasks=cfg.n_hosts, k=cfg.k,
                horizon=cfg.horizon, seed=self.seed, beta_scale=1.0)

    def _maybe_train(self, view: TelemetryView) -> None:
        cfg = view.config
        new = view.completed_jobs[self._seen:]
        if not new:
            return
        self._ensure_predictor(cfg)
        h = cfg.horizon
        for rec in new:
            t_end = min(int(rec["t"]), len(view.util_history))
            seq = self._host_window(view.util_history, t_end, h)
            m_t = self._m_t(np.asarray(
                view.util_history[t_end - 1], np.float32))
            x = np.concatenate(
                [seq.reshape(h, -1),
                 np.broadcast_to(m_t.reshape(-1),
                                 (h, m_t.size))], axis=1)
            self._xs.append(x.astype(np.float32))
            times = np.asarray(rec["times"], np.float32)
            a, b = pareto.fit_pareto_np(times[times > 0].reshape(1, -1))
            self._ys.append([float(a[0]), float(b[0])])
        self._seen = len(view.completed_jobs)
        xs = np.stack(self._xs, axis=1)              # (h, pairs, dim)
        ys = np.array(self._ys, np.float32)
        self.predictor.fit(xs, ys, epochs=self.epochs_per_update,
                           lr=self.lr)
        self.trained_pairs = len(self._xs)

    # ---------------- the prediction seam --------------------------------

    def _expected_stragglers(self, view: TelemetryView) -> float:
        self._maybe_train(view)
        cfg = view.config
        if self.trained_pairs < self.min_windows:
            return super()._expected_stragglers(view)
        n = cfg.n_hosts
        t_end = len(view.util_history)
        seq = self._host_window(view.util_history, t_end, cfg.horizon)
        m_t = self._m_t(np.asarray(view.util_history[-1], np.float32))
        pred = self.predictor.predict_features(
            seq, m_t[None], np.array([float(n)], np.float32))
        e_s = float(np.asarray(pred.e_s)[0])
        if not math.isfinite(e_s):
            return super()._expected_stragglers(view)
        return float(np.clip(e_s, 0.0, n))


@register("start-pod-service", substrates=("pod",),
          description="pod substrate as a prediction-service tenant: "
                      "per-step snapshots go to a repro.service daemon "
                      "(in-process by default), whose wire actions are "
                      "translated back to backup-shard/evict")
class ServiceBackedPodPolicy(Policy):
    """The pod substrate as a client of ``repro.service``.

    Each step the policy serializes the runtime's telemetry into one
    wire snapshot (M_H from host utilization, one ``n_hosts``-task job
    for the current horizon window, completed windows as ``done``
    records feeding the service's continuous retraining) and answers
    with the service's mitigation actions — speculate becomes a backup
    shard, rerun an eviction, via the runtime's standard translation.

    With no explicit ``client`` the policy spins up a private in-process
    :class:`~repro.service.core.PredictionService` on first use (the
    zero-infrastructure path); hand it a
    :class:`~repro.service.daemon.ServiceClient` to share a real daemon
    across pods — the tenant name is ``self.tenant``.
    """

    name = "start-pod-service"

    def __init__(self, client=None, tenant: str = "pod0",
                 trigger: str = "per_task", hysteresis: int = 2,
                 cooldown: int = 5):
        self.client = client
        self.tenant = tenant
        self.trigger = trigger
        self.hysteresis = hysteresis
        self.cooldown = cooldown
        self._admitted = False
        self._seq = 0
        self._sent_done = 0
        self.last_response: dict | None = None

    def _ensure_client(self, cfg) -> None:
        from repro.service import (LocalClient, PredictionService,
                                   Profile, ServiceConfig)
        profile = Profile(
            n_hosts=cfg.n_hosts, max_tasks=cfg.n_hosts,
            horizon=cfg.horizon, k=cfg.k, trigger=self.trigger,
            hysteresis=self.hysteresis, cooldown=self.cooldown)
        if self.client is None:
            svc = PredictionService(ServiceConfig(profile=profile))
            self.client = LocalClient(svc, self.tenant)
        if not self._admitted:
            resp = self.client.hello(profile)
            if not resp.get("ok"):
                raise RuntimeError(f"service admission failed: {resp}")
            self._admitted = True

    def decide(self, view: TelemetryView) -> list[Action]:
        from repro.policy import wire

        cfg = view.config
        if not view.extra.get("step_times"):
            return []
        self._ensure_client(cfg)
        n = cfg.n_hosts
        util = np.asarray(view.hosts.util, np.float32)
        m_h = OnlineStartPodPolicy._m_h(util)
        m_t = OnlineStartPodPolicy._m_t(util)
        online = view.hosts.online()
        window = len(view.completed_jobs)     # current window's job id
        tasks = [(h, h, h) for h in range(n) if online[h]]
        done = [{"id": int(rec["job"]),
                 "times": [float(x) for x in rec["times"]
                           if float(x) > 0]}
                for rec in view.completed_jobs[self._sent_done:]]
        snap = wire.snapshot_to_wire(
            self.tenant, self._seq, m_h,
            jobs=[wire.job_to_wire(window, n, m_t, deadline=True,
                                   tasks=tasks)],
            done=done)
        self._seq += 1
        resp = self.client.snapshot(snap)
        self.last_response = resp
        if not resp.get("ok"):
            return []                 # shed/degraded: fail open, no acts
        self._sent_done = len(view.completed_jobs)
        actions: list[Action] = []
        for job in resp.get("jobs", ()):
            for a in job.get("actions", ()):
                actions.append(wire.action_from_wire(a))
        return actions

    def forget_tasks(self, task_ids) -> None:
        # window boundary: the service's per-task trigger state is
        # scoped to the service-side controller; job ids advance per
        # window so no client-side state needs dropping
        pass


class StragglerRuntime:
    """Per-step telemetry in, mitigation actions out.

    Runtime-agnostic: it consumes step-time observations (real timers on
    hardware; simulated Pareto latencies in tests/examples), publishes a
    :class:`TelemetryView`, and executes whatever registered pod policy
    it was built with — :class:`StartPodPolicy` by default.
    """

    def __init__(self, cfg: RuntimeConfig, policy: Policy | None = None):
        self.cfg = cfg
        self.policy = policy if policy is not None else StartPodPolicy()
        self.t = 0                            # observed steps
        self.step_times: list[np.ndarray] = []
        self.chronic = np.zeros(cfg.n_hosts, np.int64)
        self.ma = np.zeros(cfg.n_hosts)
        self.evicted: set[int] = set()
        self.util_history: list[np.ndarray] = []   # (n_hosts, 4) per step
        self.completed_windows: list[dict] = []
        self._util = np.zeros((cfg.n_hosts, 4))
        self._win_elapsed = np.zeros(cfg.n_hosts)  # normalized seconds
        self._win_steps = 0
        # executed-action counters + the per-step synchronization barrier
        # (max step time over surviving hosts, with a backed-up shard
        # finishing at its backup host's pace) — the comparison surface
        # for running several policies over one trace (pod baseline grid)
        self.action_counts: dict[str, int] = {"backup_shard": 0,
                                              "evict": 0}
        self.sync_barrier_s: list[float] = []
        self._pending_backups: dict[int, int] = {}  # host -> backup

    # ------------------------------ telemetry ------------------------------

    def observe_step(self, step_times_s: np.ndarray,
                     mem_util: np.ndarray | None = None,
                     net_util: np.ndarray | None = None) -> None:
        cfg = self.cfg
        n = cfg.n_hosts
        st = np.asarray(step_times_s, float)
        self.step_times.append(st)
        # barrier accounting: backups issued at the previous decide()
        # apply to THIS step — a backed-up shard is done when either the
        # owner or its backup host finishes.  Re-validate against the
        # eviction set: a backup host chosen early in a decide() round
        # may have been evicted by a later action in the same round
        eff = st.copy()
        for h, b in self._pending_backups.items():
            if b not in self.evicted:
                eff[h] = min(eff[h], st[b])
        self._pending_backups = {}
        alive = np.ones(n, bool)
        if self.evicted:
            alive[list(self.evicted)] = False
        self.sync_barrier_s.append(
            float(eff[alive].max()) if alive.any() else 0.0)
        med = np.median(st[st > 0]) if (st > 0).any() else 1.0
        rel = st / max(med, 1e-9)
        mem = mem_util if mem_util is not None else np.zeros(n)
        net = net_util if net_util is not None else np.zeros(n)
        self._util = np.stack([np.clip(rel - 1, 0, 2), mem, net,
                               np.zeros(n)], 1)
        self.util_history.append(self._util)
        self.ma = cfg.ma_decay * self.ma + (1 - cfg.ma_decay) \
            * (rel > cfg.k)
        self.chronic = np.where(rel > cfg.k, self.chronic + 1, 0)
        self.t += 1
        # window clock: each step advances the normalized clock by 1.0;
        # a host's window-elapsed accrues its *relative* slowdown
        self._win_elapsed = self._win_elapsed + rel
        self._win_steps += 1
        if self._win_steps >= cfg.horizon:
            self.completed_windows.append(dict(
                job=len(self.completed_windows), t=self.t,
                times=self._win_elapsed.copy(),
                straggler=self._win_elapsed > cfg.k * cfg.horizon,
                hosts=np.arange(n), deadline=True))
            self._win_elapsed = np.zeros(n)
            self._win_steps = 0
            # the per-host task ids now denote a NEW window: per-task
            # policy state (histories, once-only flags) must not carry
            # over, or a chronic straggler gets mitigated once per run
            self.policy.forget_tasks(range(n))
        self.policy.observe(self.snapshot())

    # ------------------------------- the view ------------------------------

    def snapshot(self) -> TelemetryView:
        """Publish pod state in the unified telemetry geometry.

        One synthetic task per host — host h's current horizon-step
        window: ``work``/``progress`` advance one normalized unit per
        step for every host (synchronous SPMD: everyone finishes every
        step), while ``start_s`` is back-dated so ``now_s - start_s``
        equals the host's *relative* elapsed time — slow hosts age
        faster than they progress, which is precisely the straggler
        signal task-level policies key on.
        """
        cfg = self.cfg
        n = cfg.n_hosts
        now = float(self.t)
        evicted_arr = np.zeros(n, np.int64)
        if self.evicted:
            evicted_arr[list(self.evicted)] = np.iinfo(np.int64).max // 2
        w = float(self._win_steps)
        state = np.where(evicted_arr > 0, CANCELLED, RUNNING) \
            .astype(np.int8)
        tasks = TaskTelemetry(
            n=n,
            job_id=readonly(np.zeros(n, np.int64)),
            state=readonly(state),
            host=readonly(np.arange(n, dtype=np.int64)),
            work=readonly(np.full(n, float(cfg.horizon))),
            progress=readonly(np.full(n, w)),
            submit_s=readonly(now - self._win_elapsed),
            start_s=readonly(now - self._win_elapsed),
            finish_s=readonly(np.full(n, -1.0)),
            deadline_s=readonly(np.full(n, 2.0 * cfg.horizon)),
            is_deadline=readonly(np.ones(n, bool)),
            sla_weight=readonly(np.ones(n)),
            restarts=readonly(self.chronic),
            is_copy=readonly(np.zeros(n, bool)),
            orig=readonly(np.full(n, -1, np.int64)),
            delayed_until=readonly(np.zeros(n, np.int64)),
            prev_host=readonly(np.full(n, -1, np.int64)),
            req=readonly(np.zeros((n, 4))))
        ones = np.ones(n)
        hosts = HostTelemetry(
            util=readonly(self._util), speed=readonly(ones),
            cap=readonly(np.ones((n, 4))), cost=readonly(ones),
            power_max=readonly(ones), power_min=readonly(ones),
            n_tasks=readonly(np.ones(n, np.int64)),
            downtime=readonly(evicted_arr), ips=readonly(ones))
        jobs = JobTelemetry(
            start=readonly(np.zeros(1, np.int64)),
            count=readonly(np.array([n], np.int64)),
            open_count=readonly(np.array([int((state == RUNNING).sum())],
                                         np.int64)),
            done=readonly(np.zeros(1, bool)),
            deadline=readonly(np.ones(1, bool)),
            _state=state)
        return TelemetryView(
            event=EVENT_INTERVAL, t=self.t, now_s=now,
            interval_seconds=1.0, config=cfg, tasks=tasks, hosts=hosts,
            jobs=jobs, new_tasks=np.zeros(0, np.int64),
            straggler_ma=readonly(self.ma),
            completed_jobs=self.completed_windows,
            util_history=self.util_history,
            extra={"step_times": self.step_times,
                   "chronic": self.chronic})

    # ------------------------------ decision -------------------------------

    def fitted_tail(self) -> tuple[float, float]:
        return fitted_tail(self.step_times, self.cfg.horizon)

    def expected_stragglers(self) -> float:
        return expected_stragglers(self.step_times, self.cfg.n_hosts,
                                   self.cfg.k, self.cfg.horizon)

    def _pick_backup(self, host: int) -> int:
        order = [int(h) for h in np.argsort(self.ma)
                 if int(h) != host and int(h) not in self.evicted]
        return order[0] if order else host

    def decide(self) -> list[Action]:
        """Run the bound policy and execute/translate its actions.

        Host-level actions pass through; task-level actions are mapped
        onto their hosts (speculate/clone -> backup shard, rerun ->
        evict, delay -> dropped).  At most one action per host per step;
        evictions update the runtime's membership bookkeeping.
        """
        if not self.step_times:
            return []
        view = self.snapshot()
        out: list[Action] = []
        acted: set[int] = set()
        for a in self.policy.decide(view):
            kind = ActionKind(a.kind)
            backup = a.backup
            if kind in (ActionKind.BACKUP_SHARD, ActionKind.EVICT):
                h = int(a.host)
            elif kind in (ActionKind.SPECULATE, ActionKind.CLONE):
                h, kind = int(view.tasks.host[a.task]), \
                    ActionKind.BACKUP_SHARD
            elif kind is ActionKind.RERUN:
                h, kind = int(view.tasks.host[a.task]), ActionKind.EVICT
            else:                      # DELAY: no pod analogue
                continue
            if h in self.evicted or h in acted:
                continue
            acted.add(h)
            if kind is ActionKind.EVICT:
                self.evicted.add(h)
                self.action_counts["evict"] += 1
                out.append(host_action(ActionKind.EVICT, h))
            else:
                if backup is None or backup == h \
                        or backup in self.evicted:
                    backup = self._pick_backup(h)
                self.action_counts["backup_shard"] += 1
                self._pending_backups[h] = backup
                out.append(host_action(ActionKind.BACKUP_SHARD, h,
                                       backup=backup))
        return out

    def summary(self) -> dict:
        """Comparison metrics for one policy over one step trace: how
        often it acted, whom it dropped, and the synchronization barrier
        the pod actually paid (per-step max over surviving hosts, after
        crediting backup shards issued at the previous step's decide)."""
        bar = np.asarray(self.sync_barrier_s, float)
        return {
            "policy": getattr(self.policy, "name", "?"),
            "steps": self.t,
            "backup_shards": self.action_counts["backup_shard"],
            "evictions": self.action_counts["evict"],
            "evicted_hosts": sorted(self.evicted),
            "mean_sync_barrier_s": float(bar.mean()) if bar.size else 0.0,
            "p95_sync_barrier_s": (float(np.percentile(bar, 95))
                                   if bar.size else 0.0),
        }


def pretrain_igru_pod(tech, runtime: StragglerRuntime,
                      epochs: int = 200) -> None:
    """Fit an IGRU-SD policy's GRU on the pod's completed step windows.

    Reuses the cloud pretrainer's idealized-history reconstruction: each
    (host, window) pair is a task that took ``window_elapsed`` normalized
    seconds against ``horizon`` expected — the same
    completion/expected-ratio regression, sourced from pod telemetry.
    """
    from repro.sim.techniques.baselines import synthetic_progress_history

    horizon = float(runtime.cfg.horizon)
    xs, ys = [], []
    for rec in runtime.completed_windows:
        for total in rec["times"]:
            total = float(total)
            xs.append(synthetic_progress_history(
                horizon, total, horizon, 1.0))
            ys.append(total / horizon)
    if xs:
        tech.train(np.stack(xs, axis=1).astype(np.float32),
                   np.array(ys, np.float32), epochs=epochs)


def backup_mask(n_hosts: int, actions: list[Action],
                finished_in_time: np.ndarray) -> np.ndarray:
    """First-done-wins combine weights for the gradient reduce.

    finished_in_time[h] — did host h's primary shard meet the deadline.
    Returns (n_hosts,) weights: owner 1.0 if on time, else its backup 1.0;
    exactly one contribution per shard so the gradient stays exact.
    """
    w = np.asarray(finished_in_time, float).copy()
    for a in actions:
        if ActionKind(a.kind) is ActionKind.BACKUP_SHARD \
                and a.backup is not None:
            if not finished_in_time[a.host]:
                w[a.host] = 0.0  # backup host contributes this shard
    return w
