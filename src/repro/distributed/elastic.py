"""Elastic scaling: rebuild the mesh after host loss and reshard state.

Protocol (DESIGN.md §3 — the 're-run' mitigation mapped to pods):
  1. a host is declared failed (hardware fault or START chronic-straggler
     eviction);
  2. survivors agree on a new device set (here: the local simulation drops
     the host's devices);
  3. a new mesh is built with the largest (data', model) grid that fits;
  4. params/opt state are restored from the latest checkpoint with the new
     mesh's shardings (repro.train.checkpoint.restore does the re-shard);
  5. the data pipeline re-derives shard assignments from the new topology
     (SyntheticLM is stateless per (seed, step, shard) so this is free).

Everything here is exercised on fake CPU devices in tests/test_distributed.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import Mesh


@dataclasses.dataclass
class ElasticState:
    mesh: Any
    generation: int = 0
    failed_devices: tuple = ()


def largest_grid(n_devices: int, model_parallel: int) -> tuple[int, int]:
    """Largest (data, model) grid with the required model parallelism."""
    if n_devices < model_parallel:
        raise ValueError(
            f"need >= {model_parallel} devices, have {n_devices}")
    return n_devices // model_parallel, model_parallel


def remesh(state: ElasticState, lost: Sequence[int],
           model_parallel: int | None = None) -> ElasticState:
    """Drop ``lost`` device ids and build the next-generation mesh."""
    old_devices = state.mesh.devices.flatten()
    keep = [d for d in old_devices if d.id not in set(lost)]
    mp = model_parallel or state.mesh.shape.get("model", 1)
    n_data, n_model = largest_grid(len(keep), mp)
    usable = keep[:n_data * n_model]
    arr = np.array(usable).reshape(n_data, n_model)
    mesh = Mesh(arr, ("data", "model"))
    return ElasticState(mesh=mesh, generation=state.generation + 1,
                        failed_devices=state.failed_devices + tuple(lost))


def reshard(tree: Any, old_mesh, new_mesh, spec_fn) -> Any:
    """Move a pytree onto a new mesh: device_get -> device_put with the
    new mesh's shardings (checkpoint-free path for small state; large
    state goes through repro.train.checkpoint.restore)."""
    from jax.sharding import NamedSharding
    host = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)),
                                  tree)
    specs = spec_fn(host, new_mesh)
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, NamedSharding(new_mesh, s)),
        host, specs)
