from repro.distributed import compression, elastic, sharding
from repro.distributed.straggler_runtime import (ActionKind, HostAction,
                                                 RuntimeConfig,
                                                 StragglerRuntime,
                                                 backup_mask)

__all__ = ["compression", "elastic", "sharding", "StragglerRuntime",
           "RuntimeConfig", "HostAction", "ActionKind", "backup_mask"]
