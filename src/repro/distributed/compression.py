"""Gradient compression for cross-pod reduction (DCI is the scarce link).

Error-feedback int8 quantization: g_q = round(g/s) with per-tensor scale,
the quantization residual is carried into the next step (EF-SGD [Karimireddy
et al.]), making the compressed update unbiased in the limit. Also a top-k
sparsifier with the same error-feedback contract.

Used by the explicit-DP trainer variant (shard_map over the pod axis:
compress -> psum -> decompress), demonstrated in
examples/compressed_dp.py and tests/test_distributed.py.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def int8_compress(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8: returns (q int8, scale f32)."""
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_int8_reduce(grads: Any, residual: Any, axis_name: str
                   ) -> tuple[Any, Any]:
    """Error-feedback int8 all-reduce over ``axis_name`` (call inside
    shard_map). Returns (reduced fp32 grads, new residual)."""

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        # agree on ONE scale across shards (scalar pmax), so the int32 sum
        # dequantizes exactly; per-shard scales would misweight shards
        local_max = jnp.max(jnp.abs(gf))
        scale = jax.lax.pmax(local_max, axis_name) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_r = gf - q.astype(jnp.float32) * scale
        # int32 accumulator psum: 4x fewer payload bytes than f32
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(1, axis_name)
        return total.astype(jnp.float32) * scale / n, new_r

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    red = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    res = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    return red, res


def topk_compress(g: jax.Array, frac: float = 0.01
                  ) -> tuple[jax.Array, jax.Array]:
    """Keep the top-``frac`` magnitudes; returns (values, flat indices)."""
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(1, int(flat.shape[0] * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx


def topk_decompress(vals: jax.Array, idx: jax.Array, shape: tuple
                    ) -> jax.Array:
    out = jnp.zeros(int(jnp.prod(jnp.asarray(shape))), jnp.float32)
    return out.at[idx].set(vals).reshape(shape)


def ef_topk_reduce(grads: Any, residual: Any, axis_name: str,
                   frac: float = 0.01) -> tuple[Any, Any]:
    """Error-feedback top-k all-reduce (dense psum of the sparse mask's
    dense form — on a real fabric this becomes an all-gather of (vals,
    idx); the error-feedback semantics are identical)."""

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        vals, idx = topk_compress(gf, frac)
        dense = topk_decompress(gf.reshape(-1)[idx], idx, gf.shape)
        new_r = gf - dense
        n = jax.lax.psum(1, axis_name)
        return jax.lax.psum(dense, axis_name) / n, new_r

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree_util.tree_unflatten(tdef, [o[0] for o in out]),
            jax.tree_util.tree_unflatten(tdef, [o[1] for o in out]))


def zero_residual(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
