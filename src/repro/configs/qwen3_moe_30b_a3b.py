"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) d_ff=768
(expert) vocab=151936, MoE 128e top-8 [hf:Qwen/Qwen3-30B-A3B; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe", n_layers=48, d_model=2048,
    n_heads=32, n_kv_heads=4, vocab=151936, head_dim=128,
    n_experts=128, top_k=8, moe_d_ff=768, d_ff=768, rope_theta=1e6)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, vocab=256, head_dim=16,
        n_experts=8, top_k=2, moe_d_ff=32, d_ff=32)
