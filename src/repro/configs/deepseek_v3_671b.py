"""deepseek-v3-671b [moe]: 61L d_model=7168 128H d_ff=2048 (expert)
vocab=129280, MoE 256e top-8 — MLA, 1 shared + 256 routed top-8
[arXiv:2412.19437; hf].

MLA dims from the paper: q_lora 1536, kv_lora 512, qk_nope 128, qk_rope 64,
v_head 128; first 3 layers dense with d_ff 18432. The multi-token-
prediction (MTP) head is out of scope (noted in DESIGN.md deviations);
the sigmoid+bias router is approximated by softmax top-k (same dispatch
shape — DESIGN.md)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe", n_layers=61, d_model=7168,
    n_heads=128, n_kv_heads=128, vocab=129280,
    n_experts=256, top_k=8, n_shared_experts=1, moe_d_ff=2048,
    first_dense_layers=3, dense_d_ff=18432, d_ff=18432,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
    qk_rope_dim=64, v_head_dim=128)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-smoke", family="moe", n_layers=3, d_model=64,
        n_heads=4, n_kv_heads=4, vocab=256,
        n_experts=8, top_k=2, n_shared_experts=1, moe_d_ff=32,
        first_dense_layers=1, dense_d_ff=128, d_ff=128,
        use_mla=True, q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
        qk_rope_dim=8, v_head_dim=16)
