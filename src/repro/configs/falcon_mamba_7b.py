"""falcon-mamba-7b [ssm]: 64L d_model=4096 (attn-free) vocab=65024,
ssm_state=16 — mamba1 arch [arXiv:2410.05355; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm", n_layers=64, d_model=4096,
    vocab=65024, ssm_state=16, ssm_conv=4, ssm_expand=2)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-smoke", family="ssm", n_layers=2, d_model=64,
        vocab=256, ssm_state=8, ssm_conv=4, ssm_expand=2)
