"""internvl2-26b [vlm]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553 — InternViT + InternLM2 [arXiv:2404.16821; hf].

The InternViT frontend is a STUB: input_specs() provides precomputed patch
embeddings (256 tokens per image at the backbone width), prepended to the
token sequence (per the assignment's [vlm] note)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm", n_layers=48, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=16384, vocab=92553, head_dim=128,
    frontend="vit", frontend_tokens=256)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke", family="vlm", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
        frontend="vit", frontend_tokens=8)
