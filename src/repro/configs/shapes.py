"""Assigned input shapes (one set shared by all 10 LM-family archs).

  train_4k     seq 4,096   global_batch 256   -> train_step
  prefill_32k  seq 32,768  global_batch 32    -> prefill
  decode_32k   seq 32,768  global_batch 128   -> serve_step (1 new token,
                                                 KV cache of seq_len)
  long_500k    seq 524,288 global_batch 1     -> serve_step; requires
               sub-quadratic attention: run for ssm/hybrid archs only,
               structural skip for pure full-attention archs (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runnable?, reason-if-skipped) for an (arch, shape) cell."""
    if shape.name == "long_500k" and cfg.family not in \
            SUBQUADRATIC_FAMILIES:
        return False, ("structural skip: pure full-attention arch; "
                       "long_500k needs sub-quadratic attention")
    return True, ""
