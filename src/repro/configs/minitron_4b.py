"""minitron-4b [dense]: 32L d_model=3072 24H (GQA kv=8) d_ff=9216
vocab=256000 — pruned nemotron [arXiv:2407.14679; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b", family="dense", n_layers=32, d_model=3072,
    n_heads=24, n_kv_heads=8, d_ff=9216, vocab=256000, head_dim=128)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="minitron-4b-smoke", family="dense", n_layers=2, d_model=48,
        n_heads=4, n_kv_heads=2, d_ff=96, vocab=512, head_dim=16)
