"""seamless-m4t-large-v2 [audio]: 24L d_model=1024 16H d_ff=8192
vocab=256206 — enc-dec, multimodal [arXiv:2308.11596; hf].

Encoder-decoder: 24 encoder + 24 decoder layers at the listed width. The
audio frontend is a STUB: input_specs() provides precomputed frame
embeddings for the encoder (per the assignment's [audio] note)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec", n_layers=24,
    encoder_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab=256206, frontend="audio", frontend_tokens=1024)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="seamless-smoke", family="encdec", n_layers=2,
        encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=256, frontend="audio", frontend_tokens=16)
