"""demo-100m: ~126M-param dense LM for the end-to-end CPU training example
(examples/train_e2e.py) and the fault-tolerance drills. Not one of the 10
assigned archs."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="demo-100m", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=4, d_ff=3072, vocab=8192, head_dim=64)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="demo-100m-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16)
