"""The paper's own model: the Encoder-LSTM straggler predictor (START §3.2)
— not an LM; configured via repro.core. Kept here so --arch paper works in
the launcher for the simulation/benchmark paths."""
PAPER = dict(n_hosts=400, max_tasks=10, k=1.5, horizon=5)
