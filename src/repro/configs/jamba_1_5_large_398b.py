"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576, MoE 16e top-2 — Mamba+attn 1:7 interleave, MoE
[arXiv:2403.19887; hf].

Period of 8 layers: 7 mamba + 1 attention; MoE on every 2nd sublayer
(16 experts, top-2), dense SwiGLU otherwise — matching Jamba's published
1:7 attention ratio and every-other-layer MoE."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid", n_layers=72,
    d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24576, vocab=65536,
    head_dim=128, n_experts=16, top_k=2, moe_d_ff=24576, moe_every=2,
    attn_period=8, ssm_state=16, ssm_conv=4, ssm_expand=2)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke", family="hybrid", n_layers=8, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
        n_experts=4, top_k=2, moe_d_ff=128, moe_every=2, attn_period=8,
        ssm_state=8, ssm_conv=4, ssm_expand=2)
