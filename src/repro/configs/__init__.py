"""Architecture registry: --arch <id> -> ModelConfig (exact + reduced)."""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCHS = {
    "yi-6b": "yi_6b",
    "minitron-4b": "minitron_4b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "deepseek-67b": "deepseek_67b",
    "internvl2-26b": "internvl2_26b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "demo-100m": "demo_100m",  # extra: e2e example model
}


def _mod(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; choose from {list(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _mod(arch).CONFIG


def get_reduced(arch: str) -> ModelConfig:
    return _mod(arch).reduced()


def list_archs(assigned_only: bool = True) -> list[str]:
    out = list(ARCHS)
    return [a for a in out if a != "demo-100m"] if assigned_only else out
