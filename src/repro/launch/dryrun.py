import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers,
compiles and fits — on 512 placeholder CPU devices standing in for
2 x v5e-256 pods.

Per cell: build the model (EP shard_map when MoE), lower the right step
(train_step / prefill / serve_step) with explicit in/out shardings,
compile, and record memory_analysis + cost_analysis + the collective
bytes parsed from the optimized per-device HLO into a JSON artifact that
benchmarks/roofline.py turns into EXPERIMENTS.md tables.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b \
      --shape train_4k --mesh pod
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
"""
import argparse
import json
import re
import time
import traceback

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.configs.shapes import SHAPES, applicable
from repro.distributed import sharding as Sh
from repro.launch import hlo_accounting
from repro.launch.mesh import make_production_mesh
from repro.models.lm import EPSetup, Model, ShardCtx
from repro.models.specs import batch_specs, input_specs, params_specs
from repro.train import optimizer as Opt
from repro.train.trainer import TrainConfig, auto_n_micro, make_train_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "benchmarks", "artifacts", "dryrun")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")
_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
                "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
                "u64": 8}
_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|"
                       r"u64)\[([0-9,]*)\]")


def collective_bytes(hlo: str) -> dict:
    """Sum result-shape bytes of every collective op in the (per-device)
    optimized HLO. Returns {op_name: bytes, 'total': ...}."""
    out = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo.splitlines():
        for op in COLLECTIVE_OPS:
            if f" {op}(" in line or f" {op}-start(" in line:
                lhs = line.split("=", 1)[0]
                for m in _SHAPE_RE.finditer(line.split("(", 1)[0]):
                    dt, dims = m.group(1), m.group(2)
                    n = 1
                    for d in dims.split(","):
                        if d:
                            n *= int(d)
                    out[op] += n * _DTYPE_BYTES[dt]
                del lhs
                break
    out["total"] = sum(out[k] for k in COLLECTIVE_OPS)
    return out


def _mesh_dp(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in ("pod", "data")
                        if a in mesh.axis_names]))


def build_model(arch: str, mesh, dp_override: tuple | None
                = None) -> Model:
    cfg = get_config(arch)
    ep = None
    ctx = None
    if mesh is not None:
        dp = dp_override if dp_override is not None else Sh.dp_axes(mesh)
        ctx = ShardCtx(mesh=mesh, dp_axes=dp)
        if cfg.n_experts:
            nm = mesh.shape.get("model", 1)
            if cfg.n_experts % nm == 0 and nm > 1:
                ep = EPSetup(mesh=mesh, dp_axes=Sh.dp_axes(mesh),
                             ep_axis="model", n_shards=nm)
    return Model(cfg, ep=ep, shard_ctx=ctx)


def lower_cell(arch: str, shape_name: str, mesh, opt_kind: str | None
               = None, seq_shard_cache: bool = True, n_micro: int | None
               = None):
    """Lower one (arch, shape, mesh) cell; returns (lowered, meta)."""
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ns = lambda spec: NamedSharding(mesh, spec)  # noqa: E731
    nst = lambda tree: jax.tree_util.tree_map(  # noqa: E731
        ns, tree, is_leaf=lambda x: isinstance(x, P))
    meta = dict(arch=arch, shape=shape_name,
                params=cfg.param_count(),
                active_params=cfg.active_param_count())

    if shape.kind == "train":
        n_params = cfg.param_count()
        okind = opt_kind or ("adafactor" if n_params > 1.5e10
                             else "adamw")
        ocfg = Opt.OptConfig(kind=okind)
        accum = "bfloat16" if n_params > 1e11 else "float32"
        # bytes/param of live training state per model shard
        bpp = {"adamw": 14.0, "adafactor": 8.5}[okind]
        if accum == "bfloat16":
            bpp -= 2.0
        n_model = mesh.shape.get("model", 1)
        n_dev = int(np.prod(list(mesh.shape.values())))
        # layout (EXPERIMENTS.md §Perf iteration 2): dense archs whose
        # sharded state fits n_dev shards train as pure ZeRO-3 over the
        # whole pod (no TP -> no per-layer activation all-reduces); MoE
        # keeps TP/EP on the model axis.
        # activation estimate at n_micro=1 (fsdp_all can't micro-split a
        # 1-sample-per-device batch): remat carries + loss-head live set
        tokens_dev = shape.global_batch * shape.seq_len / n_dev
        logits_est = tokens_dev * cfg.padded_vocab * 2
        act_est = ((cfg.n_layers + cfg.encoder_layers) * tokens_dev
                   * cfg.d_model * 2 + logits_est)
        # big-vocab archs keep the TP-sharded head: an unsharded
        # (tokens, vocab) loss head dominates memory at n_micro=1
        fsdp_all = (cfg.n_experts == 0
                    and n_params * bpp / n_dev <= 12e9
                    and act_est <= 2.7e9 and logits_est <= 1.2e9
                    and shape.global_batch % n_dev == 0)
        if fsdp_all:
            dp = Sh.dp_axes(mesh) + ("model",)
            model = build_model(arch, mesh, dp_override=dp)
            params_sds = params_specs(model)
            pspec = Sh.param_specs(params_sds, mesh, fsdp=True, tp=False,
                                   fsdp_axes=("data", "model"))
            fsdp = True
            nm = n_micro or auto_n_micro(
                shape.global_batch, shape.seq_len, cfg.padded_vocab,
                n_dev, n_model=1,
                n_layers=cfg.n_layers + cfg.encoder_layers,
                d_model=cfg.d_model)
        else:
            dp = Sh.dp_axes(mesh)
            model = build_model(arch, mesh)
            params_sds = params_specs(model)
            fsdp = n_params * bpp / n_model > 12e9  # ~12G of 16G HBM
            pspec = Sh.param_specs(params_sds, mesh, fsdp=fsdp)
            nm = n_micro or auto_n_micro(
                shape.global_batch, shape.seq_len, cfg.padded_vocab,
                _mesh_dp(mesh), n_model=n_model,
                n_layers=cfg.n_layers + cfg.encoder_layers,
                d_model=cfg.d_model)
        tcfg = TrainConfig(n_micro=nm, accum_dtype=accum)
        meta.update(optimizer=okind, n_micro=tcfg.n_micro, fsdp=fsdp,
                    layout="fsdp_all" if fsdp_all else "tp")

        def bsp(leaf):
            first = dp if leaf.shape[0] % n_dev == 0 else None
            return P(first, *([None] * (len(leaf.shape) - 1)))

        batch = batch_specs(cfg, shape.global_batch, shape.seq_len, True)
        bspec = jax.tree_util.tree_map(bsp, batch) if fsdp_all \
            else Sh.batch_specs_tree(batch, mesh)
        opt_sds = jax.eval_shape(
            lambda: Opt.init(ocfg, params_sds))
        ospec = Opt.opt_specs(ocfg, pspec, params_sds)
        fn = make_train_step(model, ocfg, tcfg, mesh=mesh, dp_axes=dp,
                             grad_specs=pspec)
        lowered = jax.jit(
            fn,
            in_shardings=(nst(pspec), nst(ospec), nst(bspec)),
            out_shardings=(nst(pspec), nst(ospec), None),
            donate_argnums=(0, 1),
        ).lower(params_sds, opt_sds, batch)
        return lowered, meta

    model = build_model(arch, mesh)
    params_sds = params_specs(model)
    if shape.kind == "prefill":
        pspec = Sh.param_specs(
            params_sds, mesh,
            fsdp=cfg.param_count() * 2 / mesh.shape.get("model", 1)
            > 12e9)
        batch = batch_specs(cfg, shape.global_batch, shape.seq_len, False)
        bspec = Sh.batch_specs_tree(batch, mesh)
        lowered = jax.jit(
            model.prefill,
            in_shardings=(nst(pspec), nst(bspec)),
        ).lower(params_sds, batch)
        return lowered, meta

    # decode (serve_step): one token against a seq_len cache
    pspec = Sh.param_specs(
        params_sds, mesh,
        fsdp=cfg.param_count() * 2 / mesh.shape.get("model", 1) > 12e9)
    specs = input_specs(model, shape)
    cspec = Sh.cache_specs_tree(specs["caches"], mesh,
                                seq_axis_sharding=seq_shard_cache)
    tok_spec = P(Sh._dp_if_divisible(shape.global_batch, mesh), None)
    lowered = jax.jit(
        model.decode_step,
        in_shardings=(nst(pspec), nst(cspec), ns(tok_spec), ns(P())),
        out_shardings=(None, nst(cspec)),
        donate_argnums=(1,),
    ).lower(params_sds, specs["caches"], specs["tokens"],
            specs["pos"])
    return lowered, meta


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str | None = None, tag: str = "",
             **lower_kw) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    mesh_name = "multipod" if multi_pod else "pod"
    rec = dict(arch=arch, shape=shape_name, mesh=mesh_name, tag=tag)
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        lowered, meta = lower_cell(arch, shape_name, mesh, **lower_kw)
        rec.update(meta)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        totals = hlo_accounting.account(hlo)  # loop-aware (see module doc)
        coll = collective_bytes(hlo)          # raw (per-occurrence) parse
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            memory=dict(
                argument_bytes=int(getattr(mem, "argument_size_in_bytes",
                                           0)),
                output_bytes=int(getattr(mem, "output_size_in_bytes", 0)),
                temp_bytes=int(getattr(mem, "temp_size_in_bytes", 0)),
                peak_bytes=int(getattr(mem, "temp_size_in_bytes", 0))
                + int(getattr(mem, "argument_size_in_bytes", 0)),
            ),
            # loop-aware per-device accounting (hlo_accounting walker)
            flops_per_device=float(totals.flops),
            bytes_per_device=float(totals.bytes),
            transcendentals_per_device=float(totals.transcendentals),
            collective_bytes_per_device=dict(
                {k: float(v) for k, v in totals.collectives.items()},
                total=float(totals.collective_bytes)),
            unknown_trip_loops=int(totals.unknown_trip_loops),
            # raw XLA numbers for reference (loop bodies counted once)
            xla_cost_flops=float(cost.get("flops", 0.0)),
            xla_cost_bytes=float(cost.get("bytes accessed", 0.0)),
            raw_collective_bytes=coll,
            n_devices=int(np.prod(list(mesh.shape.values()))),
        )
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}{tag}: OK "
              f"flops/dev={rec['flops_per_device']:.3e} "
              f"coll/dev={totals.collective_bytes:.3e}B "
              f"temp={rec['memory']['temp_bytes']/2**30:.2f}GiB "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        print("  memory_analysis:", mem)
    except Exception as e:  # record failures as bugs to fix
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: "
              f"FAIL {type(e).__name__}: {e}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{arch}_{shape_name}_{mesh_name}{tag}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list_archs())
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="pod",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--flat-cache", action="store_true",
                    help="disable seq-axis KV cache sharding")
    ap.add_argument("--n-micro", type=int, default=None)
    args = ap.parse_args()
    out = args.out or os.path.normpath(ARTIFACT_DIR)
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]
    archs = list_archs() if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, out_dir=out, tag=args.tag,
                               seq_shard_cache=not args.flat_cache,
                               n_micro=args.n_micro)
                n_fail += rec["status"] == "error"
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
