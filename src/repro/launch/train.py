"""End-to-end training driver (CPU-runnable; same code path scales to the
production mesh via --mesh).

Features exercised here and drilled in tests:
  * synthetic-but-learnable data pipeline (repro.train.data)
  * microbatched AdamW training with sharded state
  * async checkpointing + --resume restart (fault tolerance)
  * START straggler runtime in simulation mode (--simulate-stragglers):
    per-host Pareto step-time telemetry -> E_S -> backup-shard/evict
    actions logged each interval

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch demo-100m --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch demo-100m \
      --steps 200 --ckpt /tmp/ck --resume --simulate-stragglers
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_config, get_reduced
from repro.distributed.straggler_runtime import (RuntimeConfig,
                                                 StragglerRuntime)
from repro.models.lm import Model
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, SyntheticLM
from repro.train.optimizer import OptConfig
from repro.train.trainer import TrainConfig, Trainer


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="demo-100m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--kill-at", type=int, default=None,
                    help="fault drill: hard-exit mid-run at this step")
    ap.add_argument("--simulate-stragglers", action="store_true")
    ap.add_argument("--n-hosts", type=int, default=8)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = Model(cfg)
    opt_cfg = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                        total_steps=args.steps)
    trainer = Trainer(model, mesh=None, opt_cfg=opt_cfg,
                      tcfg=TrainConfig(n_micro=args.n_micro))
    params, opt_state = trainer.init_state(seed=0)
    step_fn = trainer.compile_step()

    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch))

    start = 0
    writer = None
    if args.ckpt:
        writer = ckpt.AsyncCheckpointer(args.ckpt, keep=3)
        last = ckpt.latest_step(args.ckpt)
        if args.resume and last is not None:
            params, opt_state = ckpt.restore(
                args.ckpt, last, (params, opt_state))
            start = last
            print(f"[train] resumed from step {last}")

    runtime = None
    host_rng = np.random.default_rng(0)
    if args.simulate_stragglers:
        runtime = StragglerRuntime(RuntimeConfig(n_hosts=args.n_hosts))

    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        batch = data.batch(step)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if runtime is not None:
            # synthetic per-host step times: Pareto tail + a chronic host
            times = 1.0 + 0.05 * host_rng.pareto(2.5, args.n_hosts)
            times[args.n_hosts - 1] *= 1.0 + 0.8 * (step % 7 == 0)
            runtime.observe_step(times)
            acts = runtime.decide()
            for a in acts:
                print(f"[start-runtime] step {step}: {a.kind.value} "
                      f"host={a.host} backup={a.backup}")
        if args.kill_at is not None and step >= args.kill_at:
            if writer is not None:
                # the drill kills the training loop, not the storage layer:
                # checkpoints submitted at earlier steps would be durable
                # long before a real crash this many steps later. (Checked
                # before this step's own submit — a checkpoint submitted
                # at the crash instant would NOT survive a real crash.)
                writer.flush()
            print(f"[train] FAULT DRILL: dying at step {step}")
            raise SystemExit(42)
        if writer and step > start and step % args.ckpt_every == 0:
            writer.submit(step, (params, opt_state))
        if step % args.log_every == 0:
            print(f"[train] step {step} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"({(time.time() - t0):.1f}s)")
    if writer:
        writer.submit(args.steps, (params, opt_state))
        writer.close()
    out = {"first_loss": losses[0] if losses else None,
           "last_loss": losses[-1] if losses else None,
           "steps": len(losses)}
    print(f"[train] done: {out}")
    return out


if __name__ == "__main__":
    main()
