"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod: (data=16, model=16) = 256 chips.
Multi-pod: (pod=2, data=16, model=16) = 512 chips; the pod axis carries
only batch parallelism (gradient reduce crosses DCI once per step).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_data: int | None = None, n_model: int = 1):
    """Small mesh over however many (possibly fake) local devices exist —
    used by tests and CPU examples."""
    n = len(jax.devices())
    n_data = n_data or max(n // n_model, 1)
    return jax.make_mesh((n_data, n_model), ("data", "model"))
