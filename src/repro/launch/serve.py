"""Serving driver: batched decode with continuous batching + START
replica re-dispatch (simulated replica latencies on CPU).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch demo-100m --reduced \
      --requests 6 --max-new 12
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.models.lm import Model
from repro.serve.engine import Engine, EngineConfig, ReplicaDispatcher, \
    Request


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="demo-100m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--replicas", type=int, default=3)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dispatcher = ReplicaDispatcher(args.replicas)

    def on_step(slot, dt):
        rep = slot % args.replicas
        dispatcher.observe(rep, dt)

    engine = Engine(model, params,
                    EngineConfig(n_slots=args.slots, max_len=96),
                    on_step=on_step)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, rng.integers(4, 12))
        engine.submit(Request(req_id=i, tokens=prompt,
                              max_new=args.max_new))
        dispatcher.assign(i)
    done = engine.run()
    wall = time.time() - t0
    toks = sum(len(r.out) for r in done)
    redis = dispatcher.decide_redispatch()
    out = {"requests_done": len(done), "tokens": toks,
           "tok_per_s": round(toks / wall, 1),
           "redispatch_candidates": len(redis)}
    print(f"[serve] {out}")
    for r in done[:3]:
        print(f"  req {r.req_id}: {len(r.out)} tokens, "
              f"latency {r.finish_t - r.submit_t:.2f}s")
    return out


if __name__ == "__main__":
    main()
