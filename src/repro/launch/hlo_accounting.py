"""Loop-aware HLO accounting: FLOPs, bytes, collective bytes.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so any
program built on lax.scan (layer stacks, microbatch accumulation, recurrent
scans) is undercounted by the trip count. This walker parses the optimized
per-device HLO text and recurses through called computations, multiplying
while bodies by their ``known_trip_count`` backend_config.

Counting rules (documented in EXPERIMENTS.md §Roofline):
  * dot: 2 * prod(result_shape) * prod(lhs contracting dims)
  * elementwise/transcendental inside fusions: 1 flop per output element
  * bytes: operand + result sizes per top-level op (fusion internals are
    register traffic and not counted) — matches XLA's own convention
  * collectives: result-shape bytes per op occurrence, times loop trips
  * while: body (+cond) totals x known_trip_count (1 if unknown, flagged)
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {"pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "bf16": 2,
                "f16": 2, "s16": 2, "u16": 2, "f32": 4, "s32": 4, "u32": 4,
                "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
                "f8e4m3fn": 1, "f8e5m2": 1, "token": 0, "s2": 1, "u2": 1}

_SHAPE_RE = re.compile(
    r"(pred|bf16|f16|f32|f64|f8e4m3fn|f8e5m2|s2|u2|s4|u4|s8|u8|s16|u16|"
    r"s32|u32|s64|u64|c64|c128|token)\[([0-9,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*"n":"(\d+)"')
_LCD_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "tanh", "log", "rsqrt", "sqrt", "power",
    "logistic", "exponential-minus-one", "log-plus-one", "sine", "cosine",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even", "sign",
    "compare", "select", "and", "or", "xor", "not", "clamp", "atan2",
    "remainder", "cbrt", "erf",
}


def _shape_elems_bytes(type_str: str) -> tuple[int, int]:
    """(elements, bytes) summed over every array shape in a type string."""
    elems = 0
    byts = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


@dataclasses.dataclass
class Op:
    name: str
    rhs: str            # everything after '='
    result_type: str    # type portion of rhs (before opcode)
    opcode: str
    operands: list


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    collectives: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    unknown_trip_loops: int = 0

    def scaled(self, k: float) -> "Totals":
        t = Totals(self.flops * k, self.bytes * k,
                   self.transcendentals * k,
                   {o: v * k for o, v in self.collectives.items()},
                   self.unknown_trip_loops)
        return t

    def add(self, o: "Totals") -> None:
        self.flops += o.flops
        self.bytes += o.bytes
        self.transcendentals += o.transcendentals
        for k, v in o.collectives.items():
            self.collectives[k] += v
        self.unknown_trip_loops += o.unknown_trip_loops

    @property
    def collective_bytes(self) -> float:
        return sum(self.collectives.values())


def _split_rhs(rhs: str) -> tuple[str, str, list]:
    """rhs -> (result_type, opcode, operand names)."""
    # result type = up to the opcode token; opcode = word before '('
    i = rhs.find("(")
    # walk back from '(' to find opcode word start; handle 'opcode(' with
    # possible tuple types containing '(' — find the LAST 'word(' pattern
    m = None
    for mm in re.finditer(r"([a-z][\w\-]*)\(", rhs):
        m = mm
        # first opcode occurrence after the type is the real one: types are
        # uppercase-free too, so take the first match that is not a dtype
        if mm.group(1) not in _DTYPE_BYTES:
            break
    if m is None:
        return rhs, "", []
    opcode = m.group(1)
    result_type = rhs[:m.start()]
    # operand list: up to matching close paren
    depth = 0
    j = m.end() - 1
    end = len(rhs)
    for idx in range(j, len(rhs)):
        if rhs[idx] == "(":
            depth += 1
        elif rhs[idx] == ")":
            depth -= 1
            if depth == 0:
                end = idx
                break
    operands = _OPERAND_RE.findall(rhs[j:end])
    del i
    return result_type, opcode, operands


def parse_computations(text: str) -> dict:
    """name -> (list[Op], symbol_table name->result_type)."""
    comps: dict = {}
    cur = None
    ops: list = []
    sym: dict = {}
    entry = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if s.startswith("ENTRY "):
            m = re.match(r"ENTRY\s+%([\w.\-]+)", s)
            cur = m.group(1)
            entry = cur
            ops, sym = [], {}
            continue
        if line.startswith("%") and line.rstrip().endswith("{"):
            m = re.match(r"%([\w.\-]+)\s*\(", line)
            if m:
                cur = m.group(1)
                ops, sym = [], {}
            continue
        if s == "}":
            if cur is not None:
                comps[cur] = (ops, sym)
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        rtype, opcode, operands = _split_rhs(rhs)
        op = Op(name=name, rhs=rhs, result_type=rtype, opcode=opcode,
                operands=operands)
        ops.append(op)
        sym[name] = rtype
    return {"comps": comps, "entry": entry}


def _dot_flops(op: Op, sym: dict) -> float:
    out_elems, _ = _shape_elems_bytes(op.result_type)
    lcd = _LCD_RE.search(op.rhs)
    if not lcd or not op.operands:
        return 2.0 * out_elems  # degenerate
    lhs_type = sym.get(op.operands[0], "")
    m = _SHAPE_RE.search(lhs_type)
    if not m:
        return 2.0 * out_elems
    dims = [int(d) for d in m.group(2).split(",") if d]
    contract = 1
    for ci in lcd.group(1).split(","):
        if ci:
            contract *= dims[int(ci)]
    return 2.0 * out_elems * contract


def account_computation(name: str, module: dict, cache: dict,
                        count_bytes: bool = True) -> Totals:
    if name in cache:
        return cache[name]
    ops, sym = module["comps"].get(name, ([], {}))
    t = Totals()
    for op in ops:
        oc = op.opcode
        if oc == "while":
            body = _BODY_RE.search(op.rhs)
            cond = _COND_RE.search(op.rhs)
            trip_m = _TRIP_RE.search(op.rhs)
            trips = int(trip_m.group(1)) if trip_m else 1
            if not trip_m:
                t.unknown_trip_loops += 1
            inner = Totals()
            if body:
                inner.add(account_computation(body.group(1), module,
                                              cache))
            if cond:
                inner.add(account_computation(cond.group(1), module,
                                              cache))
            t.add(inner.scaled(trips))
            continue
        if oc in ("fusion", "call", "async-start"):
            called = _CALLS_RE.search(op.rhs)
            if called:
                inner = account_computation(called.group(1), module, cache)
                # fusion internals: flops yes, bytes no (register traffic)
                t.flops += inner.flops
                t.transcendentals += inner.transcendentals
                for k, v in inner.collectives.items():
                    t.collectives[k] += v
                t.unknown_trip_loops += inner.unknown_trip_loops
            if count_bytes:
                _, rb = _shape_elems_bytes(op.result_type)
                ob = sum(_shape_elems_bytes(sym.get(o, ""))[1]
                         for o in op.operands)
                t.bytes += rb + ob
            continue
        if oc == "dot" or oc == "convolution":
            t.flops += _dot_flops(op, sym)
        elif oc in _ELEMENTWISE:
            elems, _ = _shape_elems_bytes(op.result_type)
            t.flops += elems
            if oc in ("exponential", "tanh", "log", "rsqrt", "sqrt",
                      "power", "logistic", "sine", "cosine", "erf"):
                t.transcendentals += elems
        elif oc == "reduce":
            elems, _ = _shape_elems_bytes(
                sym.get(op.operands[0], "") if op.operands else "")
            t.flops += elems
        base = oc.replace("-start", "").replace("-done", "")
        if base in COLLECTIVES and not oc.endswith("-done"):
            _, rb = _shape_elems_bytes(op.result_type)
            t.collectives[base] += rb
        if count_bytes and oc not in ("fusion", "call"):
            _, rb = _shape_elems_bytes(op.result_type)
            ob = sum(_shape_elems_bytes(sym.get(o, ""))[1]
                     for o in op.operands)
            if oc in ("parameter", "constant", "get-tuple-element",
                      "tuple", "bitcast"):
                continue
            t.bytes += rb + ob
    cache[name] = t
    return t


def account(hlo_text: str) -> Totals:
    module = parse_computations(hlo_text)
    if module["entry"] is None:
        return Totals()
    return account_computation(module["entry"], module, {})
